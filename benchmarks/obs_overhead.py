"""Obs-plane overhead: a traced fit must cost ~nothing per round.

The `repro.obs.FitObserver` writes one JSONL event plus a handful of
registry updates per round — host-side dict and file work that must
stay invisible next to the round's device compute. Gate (the PR 8
acceptance bar): the TRACED fit's median per-round wall time is within
``HEADROOM_FRAC`` (3%) of the UNTRACED fit's.

Methodology: one warm-up fit compiles every (b, capacity) bucket, then
untraced/traced fits ALTERNATE for ``repeats`` rounds each — so slow
drift of the machine's noise floor (thermal, background load) hits both
arms equally — and the medians are compared. Both arms run the exact
same schedule (same seed, same config modulo ``trace_dir``), which the
suite asserts via round counts before comparing clocks.

Results land in ``artifacts/bench/obs_overhead.json``.
"""
from __future__ import annotations

import dataclasses
import json
import statistics
import tempfile
import time
from pathlib import Path

from benchmarks import common
from repro import api
from repro.api import FitConfig
from repro.data import synthetic

ART = Path(__file__).resolve().parent.parent / "artifacts" / "bench"

HEADROOM_FRAC = 0.03        # traced <= (1 + this) * untraced, medians

K = 50
N = 20_000
B0 = 2000
MAX_ROUNDS = 40


def _timed_fit(X, cfg):
    """(per-round wall seconds, rounds) of one full fit."""
    t0 = time.perf_counter()
    out = api.fit(X, cfg)
    wall = time.perf_counter() - t0
    rounds = max(1, len(out.telemetry))
    return wall / rounds, rounds


def main(quick: bool = True):
    print("== Obs overhead: traced vs untraced per-round wall time ==")
    repeats = 5 if quick else 9
    X = synthetic.infmnist_like(N, seed=0)
    cfg = FitConfig(k=K, algorithm="tb", b0=B0, max_rounds=MAX_ROUNDS,
                    seed=0)

    _timed_fit(X, cfg)                      # compile every bucket
    untraced, traced, trace_dirs = [], [], []
    rounds_u = rounds_t = None
    for i in range(repeats):
        per_round, rounds_u = _timed_fit(X, cfg)
        untraced.append(per_round)
        td = tempfile.mkdtemp(prefix=f"obs-overhead-{i}-")
        trace_dirs.append(td)
        per_round, rounds_t = _timed_fit(
            X, dataclasses.replace(cfg, trace_dir=td))
        traced.append(per_round)

    med_u = statistics.median(untraced)
    med_t = statistics.median(traced)
    overhead = med_t / med_u - 1.0
    print(f"  untraced: median {med_u * 1e3:7.2f} ms/round "
          f"({rounds_u} rounds x {repeats} fits)")
    print(f"  traced:   median {med_t * 1e3:7.2f} ms/round "
          f"({rounds_t} rounds x {repeats} fits)")
    print(f"  overhead: {overhead * 100:+.2f}% "
          f"(gate: <= {HEADROOM_FRAC * 100:.0f}%)")

    ok = common.check(
        "traced and untraced fits ran the same schedule",
        rounds_u == rounds_t, f"{rounds_u} vs {rounds_t} rounds")
    ok &= common.check(
        f"traced per-round wall within {HEADROOM_FRAC * 100:.0f}% of "
        f"untraced",
        overhead <= HEADROOM_FRAC, f"overhead={overhead * 100:+.2f}%")

    from repro.obs import read_events, summarize
    s = summarize(read_events(trace_dirs[-1]))
    ok &= common.check(
        "trace directory parses and matches the fit's round count",
        s["rounds"] == rounds_t,
        f"trace rounds={s['rounds']} fit rounds={rounds_t}")

    ART.mkdir(parents=True, exist_ok=True)
    (ART / "obs_overhead.json").write_text(json.dumps({
        "quick": quick, "repeats": repeats,
        "rounds_per_fit": rounds_t,
        "untraced_per_round_s": untraced,
        "traced_per_round_s": traced,
        "median_untraced_s": med_u, "median_traced_s": med_t,
        "overhead_frac": overhead, "headroom_frac": HEADROOM_FRAC,
        "config": cfg.to_dict(),
        "last_trace_summary": s,
    }, indent=1))
    print(f"  wrote {ART / 'obs_overhead.json'}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main(quick=True) else 1)
